"""Online SLO & incident plane: per-class SLO accounting on synthetic
event streams, every streaming detector exercised on hand-built inputs
(fire, re-arm, and the negative cases that must NOT fire), flight
recorder bundles (replayable, lossy-aware), lossy/truncated JSONL replay,
fleet health rollups, and a small fault-injected sim proving the
end-to-end wiring (injector -> events -> detector -> recorder)."""
import importlib.util
import json
import os

import pytest

from repro.core import events as ev
from repro.core.events import EventBus
from repro.obs import (DetectorConfig, DetectorSuite, FlightRecorder,
                       HealthReport, MetricsRegistry, SloTracker, Tracer,
                       bind_engine_probes, dump_events_jsonl,
                       events_from_dicts, load_events_jsonl,
                       write_events_jsonl)

REPO = os.path.join(os.path.dirname(__file__), "..")

_spec = importlib.util.spec_from_file_location(
    "trace_report", os.path.join(REPO, "scripts", "trace_report.py"))
trace_report = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(trace_report)


def _e(k, t, sid=1, **data):
    return {"kind": k, "t": t, "sid": sid, "data": data}


def _tick(t, *, waiting=0, free=900, total=1000, elapsed=1.0,
          swapins=0, backlog=0):
    return _e(ev.TICK, t, -1, elapsed=elapsed, waiting=waiting,
              free_blocks=free, total_blocks=total, n_swapins=swapins,
              n_swapouts=0, cpu_backlog=backlog)


# --- SLO accounting ----------------------------------------------------------

def test_slo_clean_session_is_goodput():
    slo = SloTracker.replay(events_from_dicts([
        _e(ev.SUBMIT, 0.0, slo_class="standard", slo_alpha=3.0, ideal_s=2.0),
        _e(ev.GPU_FIRST_TOKEN, 1.0, ttft=1.0),
        _e(ev.DECODE_STEP, 1.4, start=1.0, tokens=8),
        _e(ev.TOOL_ENQUEUE, 2.0, kind="search"),
        _e(ev.TOOL_END, 4.0, kind="search", duration=1.5),
        _e(ev.FINISH, 5.0, latency=5.0),
    ]))
    c = slo.report()["classes"]["standard"]
    assert c["sessions"] == c["finished"] == c["good"] == 1
    assert c["goodput_frac"] == 1.0 and c["violated_sessions"] == 0
    assert all(n == 0 for n in c["violations"].values())
    # quantile rollup fed from the same stream
    assert c["quantiles"]["ttft_s"]["count"] == 1
    assert c["quantiles"]["tool_overhead_s"]["mean"] == pytest.approx(0.5)


def test_slo_every_metric_can_violate():
    # interactive bounds: ttft 2.0, itl 0.25, tool_overhead 15.0, alpha 2.0
    slo = SloTracker.replay(events_from_dicts([
        _e(ev.SUBMIT, 0.0, slo_class="interactive", slo_alpha=2.0,
           ideal_s=2.0),
        _e(ev.GPU_FIRST_TOKEN, 5.0, ttft=5.0),            # > 2.0
        _e(ev.DECODE_STEP, 9.0, start=5.0, tokens=2),     # itl 2.0 > 0.25
        _e(ev.TOOL_ENQUEUE, 10.0, kind="t"),
        _e(ev.TOOL_END, 40.0, kind="t", duration=1.0),    # overhead 29 > 15
        _e(ev.FINISH, 50.0, latency=50.0),                # > 2 x 2.0
    ]))
    c = slo.report()["classes"]["interactive"]
    assert c["violations"] == {"ttft_s": 1, "itl_s": 1,
                               "tool_overhead_s": 1, "e2e_s": 1}
    assert c["violated_sessions"] == 1
    assert c["goodput_frac"] == 0.0


def test_slo_no_ideal_is_exempt_and_reject_counted():
    slo = SloTracker.replay(events_from_dicts([
        _e(ev.SUBMIT, 0.0, slo_class="standard"),          # no ideal_s
        _e(ev.FINISH, 500.0, latency=500.0),
        _e(ev.REJECT, 1.0, sid=2),
    ]))
    rep = slo.report()
    assert rep["classes"]["standard"]["good"] == 1         # exempt, not bad
    assert rep["rejected"] == 1


def test_slo_unknown_class_registered_and_resubmit_keeps_state():
    slo = SloTracker.replay(events_from_dicts([
        _e(ev.SUBMIT, 0.0, slo_class="premium", ideal_s=1.0),
        # cluster re-placement re-emits SUBMIT; state must survive it
        _e(ev.SUBMIT, 10.0, slo_class="standard", ideal_s=99.0),
        _e(ev.FINISH, 20.0, latency=20.0),
    ]))
    rep = slo.report()
    assert "premium" in rep["classes"] and "standard" not in rep["classes"]
    # judged against the ORIGINAL ideal_s=1.0: 20 > 3 x 1 -> violated
    assert rep["classes"]["premium"]["good"] == 0


# --- detectors: decode_livelock ----------------------------------------------

def test_decode_livelock_fires_once_then_rearms_on_next_step():
    rows = [_e(ev.DECODE_STEP, 0.0, start=0.0, tokens=4, decoded=8)]
    rows += [_tick(float(i)) for i in range(1, 421)]
    suite = DetectorSuite.replay(events_from_dicts(rows))
    assert suite.count("decode_livelock") == 1
    inc = suite.incidents[0]
    assert inc["sid"] == 1 and inc["evidence"]["ticks_stalled"] >= 400
    # silent without a fresh DECODE_STEP (disarmed), refires after one
    rows += [_tick(float(i)) for i in range(421, 440)]
    rows += [_e(ev.DECODE_STEP, 440.0, start=439.0, tokens=4, decoded=12)]
    rows += [_tick(float(i)) for i in range(441, 900)]
    suite = DetectorSuite.replay(events_from_dicts(rows))
    assert suite.count("decode_livelock") == 2


def test_decode_livelock_silent_after_session_leaves_decode():
    for leave in (ev.FINISH, ev.TOOL_ENQUEUE, ev.PREEMPT):
        rows = [_e(ev.DECODE_STEP, 0.0, start=0.0, tokens=4),
                _e(leave, 1.0, kind="t")]
        rows += [_tick(float(i)) for i in range(2, 500)]
        suite = DetectorSuite.replay(events_from_dicts(rows))
        assert suite.count("decode_livelock") == 0, leave


# --- detectors: tool_stall ---------------------------------------------------

def test_tool_stall_uses_promise_from_enqueue():
    # TOOL_START carries no expected_s (the promise rides TOOL_ENQUEUE);
    # bound = max(min_s=60, 4 x 10) = 60s past the start
    rows = [_e(ev.TOOL_ENQUEUE, 0.0, sid=2, kind="test_runner",
               expected_s=10.0),
            _e(ev.TOOL_START, 1.0, sid=2, kind="test_runner",
               queue_wait=1.0)]
    rows += [_tick(float(i)) for i in range(2, 90)]
    suite = DetectorSuite.replay(events_from_dicts(rows))
    assert suite.count("tool_stall") == 1
    evd = suite.incidents[0]["evidence"]
    assert evd["expected_s"] == 10.0 and evd["bound_s"] == 60.0
    assert evd["running_s"] > 60.0


def test_tool_stall_silent_when_tool_finishes_in_time():
    rows = [_e(ev.TOOL_ENQUEUE, 0.0, sid=2, kind="t", expected_s=10.0),
            _e(ev.TOOL_START, 1.0, sid=2, kind="t")]
    rows += [_tick(float(i)) for i in range(2, 40)]
    rows += [_e(ev.TOOL_END, 40.0, sid=2, kind="t", duration=39.0)]
    rows += [_tick(float(i)) for i in range(41, 200)]
    suite = DetectorSuite.replay(events_from_dicts(rows))
    assert suite.count("tool_stall") == 0


def test_tool_stall_ignores_queueing_before_start():
    # 200s stuck in the core-pool queue, then a quick run: clean.
    # The stall clock starts at TOOL_START, so queueing never trips it.
    rows = [_e(ev.TOOL_ENQUEUE, 0.0, sid=2, kind="t", expected_s=10.0)]
    rows += [_tick(float(i)) for i in range(1, 200)]
    rows += [_e(ev.TOOL_START, 200.0, sid=2, kind="t", queue_wait=200.0),
             _e(ev.TOOL_END, 210.0, sid=2, kind="t", duration=10.0)]
    rows += [_tick(float(i)) for i in range(211, 280)]
    suite = DetectorSuite.replay(events_from_dicts(rows))
    assert suite.count("tool_stall") == 0


# --- detectors: admission_stall ----------------------------------------------

def test_admission_stall_requires_free_pool():
    stalled = [_tick(float(i), waiting=3, free=900, total=1000)
               for i in range(1, 350)]
    suite = DetectorSuite.replay(events_from_dicts(stalled))
    assert suite.count("admission_stall") == 1
    evd = suite.incidents[0]["evidence"]
    assert evd["free_frac"] >= 0.5 and evd["waiting_streak"] >= 300
    # same streak under genuine KV backpressure: NOT a control-plane stall
    packed = [_tick(float(i), waiting=3, free=100, total=1000)
              for i in range(1, 350)]
    suite = DetectorSuite.replay(events_from_dicts(packed))
    assert suite.count("admission_stall") == 0


def test_admission_stall_reset_by_round0_submit():
    rows = []
    for i in range(1, 600):
        rows.append(_tick(float(i), waiting=3))
        if i % 200 == 0:                    # admission is making progress
            rows.append(_e(ev.GPU_SUBMIT, float(i), sid=5, round=0))
    suite = DetectorSuite.replay(events_from_dicts(rows))
    assert suite.count("admission_stall") == 0


# --- detectors: swap_storm ---------------------------------------------------

def test_swap_storm_fires_on_io_saturated_window():
    rows = [_tick(float(i), elapsed=0.2, swapins=2) for i in range(1, 70)]
    suite = DetectorSuite.replay(events_from_dicts(rows))
    assert suite.count("swap_storm") == 1
    assert suite.incidents[0]["evidence"]["io_frac"] >= 0.8


def test_swap_storm_silent_below_io_fraction():
    # every other tick swaps: io_frac 0.5 < 0.8
    rows = [_tick(float(i), elapsed=0.2, swapins=i % 2)
            for i in range(1, 200)]
    suite = DetectorSuite.replay(events_from_dicts(rows))
    assert suite.count("swap_storm") == 0


# --- detectors: cpu_queue_collapse -------------------------------------------

def test_cpu_collapse_needs_level_and_growth():
    ramp = [_tick(float(i), backlog=i) for i in range(1, 40)]
    suite = DetectorSuite.replay(events_from_dicts(ramp))
    assert suite.count("cpu_queue_collapse") == 1
    assert suite.incidents[0]["evidence"]["cpu_backlog"] >= 16
    # a steady (non-growing) backlog is load, not collapse
    flat = [_tick(float(i), backlog=20) for i in range(1, 200)]
    suite = DetectorSuite.replay(events_from_dicts(flat))
    assert suite.count("cpu_queue_collapse") == 0


# --- detectors: kv_thrash ----------------------------------------------------

def test_kv_thrash_counts_round_trips_in_window():
    rows = []
    for i in range(6):      # 3 demote<->promote round trips over 50s
        rows.append(_e(ev.DEMOTE if i % 2 == 0 else ev.PROMOTE,
                       10.0 * i, sid=3, blocks=4))
    suite = DetectorSuite.replay(events_from_dicts(rows))
    assert suite.count("kv_thrash") == 1
    assert suite.incidents[0]["evidence"]["migrations"] == 6
    # same migrations spread over 500s: slow churn, not thrash
    slow = [_e(ev.DEMOTE if i % 2 == 0 else ev.PROMOTE, 100.0 * i, sid=3)
            for i in range(6)]
    suite = DetectorSuite.replay(events_from_dicts(slow))
    assert suite.count("kv_thrash") == 0


# --- detectors: event_loss ---------------------------------------------------

def test_event_loss_live_from_ring_eviction():
    bus = EventBus(max_log=4)
    suite = DetectorSuite(bus)
    for i in range(10):
        bus.emit("filler", float(i), i)
    assert suite.count("event_loss") == 0     # not yet observed
    bus.emit(ev.TICK, 10.0, -1, elapsed=1.0)
    assert suite.count("event_loss") == 1
    assert suite.incidents[0]["evidence"]["source"] == "ring"
    # 6 fillers evicted + the TICK's own eviction; the INCIDENT the suite
    # emits back onto the full ring bumps the live counter past the record
    assert suite.incidents[0]["evidence"]["total_dropped"] == 7
    assert bus.dropped >= 7


def test_event_loss_replay_from_trace_meta(tmp_path):
    p = tmp_path / "lossy.jsonl"
    write_events_jsonl(events_from_dicts([_e(ev.SUBMIT, 0.0)]), str(p),
                       dropped=7)
    suite = DetectorSuite.replay(load_events_jsonl(str(p)))
    assert suite.count("event_loss") == 1
    assert suite.incidents[0]["evidence"]["dropped"] == 7
    # a clean dump replays without the incident
    clean = tmp_path / "clean.jsonl"
    write_events_jsonl(events_from_dicts([_e(ev.SUBMIT, 0.0)]), str(clean))
    assert DetectorSuite.replay(load_events_jsonl(str(clean))).count() == 0


# --- clean stream -> zero incidents ------------------------------------------

def test_clean_lifetime_stream_no_incidents():
    rows = [
        _e(ev.SUBMIT, 0.0, slo_class="standard", ideal_s=5.0),
        _e(ev.GPU_SUBMIT, 1.0, round=0),
        _e(ev.DECODE_STEP, 2.0, start=1.0, tokens=8),
        _e(ev.TOOL_ENQUEUE, 3.0, kind="t", expected_s=2.0),
        _e(ev.TOOL_START, 3.5, kind="t"),
        _e(ev.TOOL_END, 5.5, kind="t", duration=2.0),
        _e(ev.FINISH, 8.0, latency=8.0),
    ]
    rows += [_tick(float(i)) for i in range(9, 120)]
    suite = DetectorSuite.replay(events_from_dicts(rows))
    assert suite.count() == 0 and suite.incidents == []


# --- flight recorder ---------------------------------------------------------

def _thrash(bus, sid=3):
    for i in range(6):
        bus.emit(ev.DEMOTE if i % 2 == 0 else ev.PROMOTE,
                 10.0 * i, sid, blocks=4)


def test_flight_recorder_dumps_replayable_bundle(tmp_path):
    bus = EventBus()
    DetectorSuite(bus)
    rec = FlightRecorder(bus, str(tmp_path / "bundles"))
    bus.emit(ev.SUBMIT, 0.0, 3)
    _thrash(bus)
    assert len(rec.bundles) == 1 and rec.incidents_seen == 1
    bundle = rec.bundles[0]
    assert os.path.basename(bundle).endswith("kv_thrash")
    inc = json.load(open(os.path.join(bundle, "incident.json")))
    assert inc["incident"]["kind"] == "kv_thrash"
    assert inc["incident"]["sid"] == 3
    assert inc["ring"]["dropped"] == 0
    # events.jsonl replays through the standard pipeline
    events = load_events_jsonl(os.path.join(bundle, "events.jsonl"))
    assert any(e.kind == ev.INCIDENT for e in events)
    Tracer.replay(events)                                  # no raise
    assert trace_report.main(
        [os.path.join(bundle, "events.jsonl"), "--strict"]) == 0


def test_flight_recorder_lossy_ring_fails_strict_report(tmp_path, capsys):
    bus = EventBus(max_log=3)                  # evicts: dump will be lossy
    DetectorSuite(bus)
    rec = FlightRecorder(bus, str(tmp_path / "bundles"))
    _thrash(bus)
    path = os.path.join(rec.bundles[0], "events.jsonl")
    assert trace_report.main([path]) == 0      # warns, still reports
    assert "lossy" in capsys.readouterr().err
    assert trace_report.main([path, "--strict"]) == 2


def test_flight_recorder_caps_bundles(tmp_path):
    bus = EventBus()
    DetectorSuite(bus)
    rec = FlightRecorder(bus, str(tmp_path / "b"), max_bundles=1)
    _thrash(bus, sid=3)
    _thrash(bus, sid=4)                        # second incident, no dump
    assert rec.incidents_seen == 2 and len(rec.bundles) == 1


# --- lossy / truncated JSONL replay ------------------------------------------

def _lifetime_bus():
    bus = EventBus()
    for d in [_e(ev.SUBMIT, 0.0, tokens=64, rounds=1),
              _e(ev.GPU_SUBMIT, 1.0, round=0),
              _e(ev.PREFILL_CHUNK, 2.0, start=1.0, tokens=64, round=0),
              _e(ev.DECODE_STEP, 3.0, start=2.0, tokens=8, round=0),
              _e(ev.GPU_END, 3.0, round=0),
              _e(ev.FINISH, 3.0, latency=3.0)]:
        bus.emit(d["kind"], d["t"], d["sid"], **d["data"])
    return bus


def test_tracer_replay_tolerates_truncated_dump(tmp_path):
    p = tmp_path / "events.jsonl"
    n = dump_events_jsonl(_lifetime_bus(), str(p))
    assert n == 6
    lines = p.read_text().splitlines()
    # dump cut off mid-write: final line half-gone, plus line noise
    damaged = lines[:-1] + [lines[-1][: len(lines[-1]) // 2], "{not json"]
    p.write_text("\n".join(damaged) + "\n")
    events = load_events_jsonl(str(p))
    assert len(events) == n                    # header + events - FINISH
    tr = Tracer.replay(events)
    assert tr.finished_count == 0              # FINISH was the cut line
    cp = tr.critical_path(1, allow_unfinished=True)
    assert cp is not None and cp["e2e"] > 0    # partial timeline survives
    rows, dropped = trace_report.rows_from_jsonl(str(p))
    assert dropped == 0 and rows == []


def test_trace_report_rows_surface_header_drop_count(tmp_path):
    p = tmp_path / "events.jsonl"
    write_events_jsonl(list(_lifetime_bus().log), str(p), dropped=11)
    rows, dropped = trace_report.rows_from_jsonl(str(p))
    assert dropped == 11 and len(rows) == 1
    assert trace_report.main([str(p), "--strict"]) == 2


# --- fleet health rollup -----------------------------------------------------

def test_health_report_status_ladder():
    from repro.distributed.router import ClusterRouter, RouterConfig
    router = ClusterRouter(RouterConfig(heartbeat_timeout=5.0))
    for rid in ("r0", "r1"):
        router.register(rid, now=0.0)
        router.heartbeat(rid, kv_utilization=0.4, tool_backlog=0,
                         active_sessions=2, step_latency=0.01, now=1.0)
    assert HealthReport.collect(router).status == "healthy"

    # incidents on a live replica escalate to degraded
    suite = DetectorSuite()
    suite._fire("tool_stall", 10.0, 7, {"running_s": 99.0})
    rep = HealthReport.collect(router, detectors={"r0": suite})
    assert rep.status == "degraded"
    assert rep.incidents == {"tool_stall": 1}
    r0 = next(r for r in rep.replicas if r.rid == "r0")
    assert r0.status == "degraded" and r0.incidents == {"tool_stall": 1}
    assert "tool_stallx1" in rep.render()

    # heartbeat timeout: dead replica wins the ladder
    router.heartbeat("r0", kv_utilization=0.4, tool_backlog=0,
                     active_sessions=2, step_latency=0.01, now=20.0)
    router.check_failures(now=20.0)            # r1 last beat at t=1
    rep = HealthReport.collect(router)
    assert rep.status == "critical"
    assert rep.fleet["alive"] == 1
    assert rep.render().startswith("fleet health: CRITICAL")
    assert rep.to_dict()["replicas"][1]["status"] == "dead"


def test_health_report_includes_slo_rollup():
    from repro.distributed.router import ClusterRouter, RouterConfig
    router = ClusterRouter(RouterConfig())
    router.register("r0", now=0.0)
    router.heartbeat("r0", kv_utilization=0.1, tool_backlog=0,
                     active_sessions=0, step_latency=0.01, now=0.5)
    slo = SloTracker.replay(events_from_dicts([
        _e(ev.SUBMIT, 0.0, slo_class="standard", ideal_s=2.0),
        _e(ev.FINISH, 3.0, latency=3.0),
    ]))
    rep = HealthReport.collect(router, slo=slo)
    assert rep.slo["classes"]["standard"]["good"] == 1
    assert "slo[standard]: goodput 100.00%" in rep.render()


# --- metrics: live gauges ----------------------------------------------------

def test_gauge_set_fn_is_live_until_overwritten():
    reg = MetricsRegistry()
    box = {"v": 1.0}
    g = reg.gauge("x")
    g.set_fn(lambda: box["v"])
    assert reg.snapshot()["gauges"]["x"] == 1.0
    box["v"] = 5.0
    assert reg.snapshot()["gauges"]["x"] == 5.0
    g.set(2.0)                                 # explicit set detaches the fn
    box["v"] = 9.0
    assert reg.snapshot()["gauges"]["x"] == 2.0


# --- sim integration (fault injector -> detector -> recorder) ----------------

@pytest.fixture()
def _sim_parts():
    # sessions are regenerated per test: the sim mutates them in place
    from repro.configs.qwen3_coder_30b import CONFIG
    from repro.engine.backend import SimBackend
    from repro.models.perf_model import H100
    from repro.workloads.generator import WorkloadSpec, generate
    spec = WorkloadSpec(regime="S-ILR1", arrival_rate=0.2, n_sessions=10,
                        seed=7, max_context=40_000, tool_time_scale=0.25,
                        slo_class="standard")
    sessions = generate(spec, CONFIG, H100)
    return CONFIG, H100, SimBackend, sessions


def _engine(CONFIG, H100, SimBackend):
    from repro.engine.engine import Engine, EngineConfig
    return Engine(EngineConfig(total_kv_blocks=16_384, block_size=32,
                               token_budget=8192, cpu_slots=32),
                  "mars", SimBackend(CONFIG, H100), bus=EventBus())


def test_clean_sim_run_produces_zero_incidents(_sim_parts):
    from repro.engine.engine import run_sim
    CONFIG, H100, SimBackend, sessions = _sim_parts
    eng = _engine(CONFIG, H100, SimBackend)
    suite = DetectorSuite.install(eng)
    slo = SloTracker.install(eng)
    finished, _ = run_sim(eng, list(sessions), max_time=5000.0)
    assert len(finished) == 10
    assert suite.count() == 0, suite.incidents
    rep = slo.report()
    assert rep["classes"]["standard"]["sessions"] == 10
    assert rep["classes"]["standard"]["finished"] == 10


def test_stuck_tool_sim_detected_and_recorded(_sim_parts, tmp_path):
    from repro.engine.engine import run_sim
    from repro.engine.faults import Fault, FaultPlan
    CONFIG, H100, SimBackend, sessions = _sim_parts
    eng = _engine(CONFIG, H100, SimBackend)
    # thresholds shrunk so the tiny workload trips them well before it
    # drains; slo_bench proves the production defaults at scale
    suite = DetectorSuite.install(eng, config=DetectorConfig(
        tool_stall_factor=2.0, tool_stall_min_s=5.0))
    rec = FlightRecorder.install(eng, str(tmp_path / "bundles"))
    plan = FaultPlan([Fault(kind="stuck_tool", at_s=30.0,
                            stretch=1e6)]).install(eng)
    run_sim(eng, list(sessions), max_time=3000.0)
    assert plan.faults[0].hits >= 1
    assert suite.count("tool_stall") >= 1
    evd = next(i for i in suite.incidents
               if i["kind"] == "tool_stall")["evidence"]
    assert evd["running_s"] > evd["bound_s"]
    # the recorder froze a bundle the moment the detector fired
    assert rec.bundles, "incident must produce a flight-recorder bundle"
    inc = json.load(open(os.path.join(rec.bundles[0], "incident.json")))
    assert inc["incident"]["kind"] == "tool_stall"
    assert inc["critical_path"] is not None    # stuck session attributed


# --- workload spec: SLO class stamping ---------------------------------------

def test_workload_slo_class_stamp_is_rng_neutral():
    from repro.configs.qwen3_coder_30b import CONFIG
    from repro.models.perf_model import H100
    from repro.workloads.generator import WorkloadSpec, generate
    kw = dict(regime="S-ILR1", arrival_rate=0.2, n_sessions=6, seed=11,
              max_context=40_000)
    tagged = generate(WorkloadSpec(slo_class="interactive", **kw),
                      CONFIG, H100)
    plain = generate(WorkloadSpec(**kw), CONFIG, H100)
    assert all(s.meta["slo_class"] == "interactive" for s in tagged)
    assert all("slo_class" not in s.meta for s in plain)
    # stamping consumes no randomness: identical arrivals either way
    assert [s.arrival_time for s in tagged] == \
        [s.arrival_time for s in plain]
    assert [s.ideal_time for s in tagged] == \
        [s.ideal_time for s in plain]
