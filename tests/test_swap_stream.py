"""Async swap stream tests: double-buffered staging reuse, future-gated
``HostTier.ready`` (with the sim-clock path pinned bit-identical), engine
deferral of unresolved swap-ins, in-flight stale-gen invalidation falling
back to recompute, and the live paged runner moving real transfers through
the background worker without changing greedy tokens."""
import time

import pytest

from repro.core import events as ev
from repro.core.policies import KVAction
from repro.core.session import KVState, Phase, Round, make_session
from repro.engine.backend import SimBackend
from repro.engine.engine import Engine, EngineConfig, run_sim
from repro.kvcache import (HostTier, HostTierConfig, SwapStream,
                           TransferFuture, resolved_future)

from repro.configs.qwen3_coder_30b import CONFIG as QWEN3
from repro.models.perf_model import H100


# ---------------------------------------------------------------------------
# stream: double-buffered staging + futures
# ---------------------------------------------------------------------------

def test_staging_double_buffer_reuse():
    """5 transfers over 2 staging buffers: never more than 2 in flight,
    both slots recycled, FIFO results intact."""
    st = SwapStream(n_buffers=2)
    futs = []
    for i in range(5):
        slot = st.staging.acquire()          # backpressures beyond 2

        def job(i=i, slot=slot):
            try:
                time.sleep(0.005)
                return i
            finally:
                st.staging.release(slot)

        futs.append(st.submit(job, sid=i, direction="d2h"))
    assert [f.result(timeout=10) for f in futs] == list(range(5))
    assert st.staging.acquires == 5
    assert st.staging.max_in_flight <= 2
    assert st.staging.reuses == 3            # 5 acquires over 2 buffers
    assert st.d2h_completed == 5
    st.close()


def test_transfer_future_error_propagates():
    st = SwapStream()
    fut = st.submit(lambda: 1 / 0, direction="h2d")
    with pytest.raises(ZeroDivisionError):
        fut.result(timeout=10)
    assert fut.done()
    st.close()


# ---------------------------------------------------------------------------
# host tier: future-gated ready / time_to_ready
# ---------------------------------------------------------------------------

def _tier():
    return HostTier(HostTierConfig(capacity_blocks=10, pcie_bw=1e9),
                    bytes_per_token=1e6, block_size=32)


def test_host_tier_sim_clock_bit_identical():
    """Regression (no futures attached): ``ready`` flips exactly at the
    modeled ``now + swap_seconds(tokens)`` and ``time_to_ready`` is exactly
    the modeled remainder — the sim path keeps the cost model as its
    "future", unchanged by the stream refactor."""
    ht = _tier()
    sec = ht.store(1, tokens=100, blocks=4, now=2.0)
    assert sec == pytest.approx(ht.cfg.base_latency_s + 0.1)
    assert ht.time_to_ready(1, 2.0) == pytest.approx(sec)
    assert ht.time_to_ready(1, 2.0 + sec / 2) == pytest.approx(sec / 2)
    assert not ht.ready(1, 2.0 + 0.999 * sec)
    assert ht.ready(1, 2.0 + sec)
    assert ht.time_to_ready(1, 5.0 + sec) == 0.0
    assert ht.next_event_time(2.0) == pytest.approx(2.0 + sec)
    assert ht.time_to_ready(99, 0.0) is None


def test_transfer_future_gates_host_tier_ready():
    """Future-gated entries ignore the modeled clock entirely: not ready at
    any ``now`` until the real transfer resolves, never a sim timer."""
    ht = _tier()
    ht.store(5, tokens=100, blocks=4, now=0.0)
    ht.mark_in_flight(5)
    assert not ht.ready(5, 1e9)              # modeled time long past
    assert ht.time_to_ready(5, 1e9) is None  # wall clock decides
    assert ht.next_event_time(0.0) is None   # not a sim timer event
    fut = TransferFuture(5, "d2h")
    ht.attach_future(5, fut)
    assert not ht.ready(5, 1e9)
    fut._resolve(None)
    assert ht.ready(5, 0.0)
    assert ht.time_to_ready(5, 0.0) == 0.0
    ht.attach_future(404, resolved_future())  # unknown sid: tolerated no-op
    assert not ht.ready(404, 0.0)


# ---------------------------------------------------------------------------
# engine: deferral handshake + stale-gen fallback (stubbed async backend)
# ---------------------------------------------------------------------------

class _FakeFuture:
    def __init__(self):
        self._done = False

    def done(self):
        return self._done

    def resolve(self):
        self._done = True


class _AsyncStubBackend(SimBackend):
    """SimBackend wearing the async-swap surface: swap-outs hand the engine
    controllable fake futures via the BatchWork handshake, prefetch
    requests are recorded, nothing actually copies."""
    supports_async_swap = True

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.out_futs = {}
        self.in_futs = {}
        self.prefetch_requests = []
        self.dropped = []
        self.swapin_costs = []        # (sid, meta["swap_cost_s"]) at restore

    def run_batch(self, work, now):
        for s, _ in work.swapins:
            self.swapin_costs.append((s.sid, s.meta.get("swap_cost_s")))
        for s, _ in work.swapouts:
            fut = self.out_futs.setdefault(s.sid, _FakeFuture())
            work.swap_futures[s.sid] = fut
        return super().run_batch(work, now)

    def prefetch_swap_in(self, sid):
        self.prefetch_requests.append(sid)
        return self.in_futs.setdefault(sid, _FakeFuture())

    def drop_host(self, sid):
        self.dropped.append(sid)


def _async_engine(blocks=512, **cfg_kw):
    backend = _AsyncStubBackend(QWEN3, H100)
    eng = Engine(EngineConfig(total_kv_blocks=blocks, block_size=32,
                              token_budget=8192, max_decode_batch=64,
                              decode_granularity=8, cpu_slots=4, **cfg_kw),
                 "fcfs", backend)
    eng.policy.on_tool_yield = lambda s, now: (KVAction.OFFLOAD, 0.0)
    return eng, backend


def _tick_until(eng, now, pred, limit=200, dt=0.05):
    for _ in range(limit):
        if pred():
            return now
        elapsed, _prog = eng.tick(now)
        now += max(elapsed, dt)
    raise AssertionError("condition not reached")


def test_engine_defers_unresolved_swap_in():
    """A re-admitted session whose swap transfers have not resolved is
    deferred (not restored, not stalled on); once both futures resolve the
    restore executes and charges swap_cost_s = 0 (the crossing overlapped
    other compute)."""
    eng, backend = _async_engine()
    s = make_session(0.0, [Round(4096, 8, "t", 10.0),
                           Round(64, 8, None, 0.0)],
                     ideal_time=1.0, sid=77001)
    eng.submit(s)
    now = _tick_until(eng, 0.0, lambda: s.phase == Phase.TOOL)
    assert s.kv_state == KVState.SWAPPED and eng.host.holds(s.sid)
    # mark_in_flight: never restorable off the modeled clock alone
    assert not eng.host.ready(s.sid, now + 1e9)
    # drain the queued swap-out batch -> the real future is attached
    now = _tick_until(eng, now, lambda: s.sid in backend.out_futs, limit=3)
    now += 11.0                              # tool long finished
    for _ in range(3):                       # deferral is stable
        elapsed, _ = eng.tick(now)
        now += max(elapsed, 0.05)
    assert s.phase == Phase.READY_PREFILL    # re-admitted...
    assert s.kv_state == KVState.SWAPPED     # ...but not restored
    assert backend.prefetch_requests == []   # D2H unresolved: no prefetch
    backend.out_futs[s.sid].resolve()
    elapsed, _ = eng.tick(now)
    now += max(elapsed, 0.05)
    assert backend.prefetch_requests == [s.sid]   # H2D launched...
    assert s.kv_state == KVState.SWAPPED          # ...restore still deferred
    backend.in_futs[s.sid].resolve()
    now = _tick_until(eng, now, lambda: s.kv_state == KVState.RESIDENT)
    assert backend.swapin_costs == [(s.sid, 0.0)]  # overlapped: free restore
    _tick_until(eng, now, lambda: s.phase == Phase.FINISHED)
    assert eng.host.hits == 1 and eng.host.used_blocks == 0
    eng.check_invariants()


def test_inflight_stale_gen_falls_back_to_recompute():
    """Radix-shared blocks recorded in a swap record are gen-certified at
    restore; evicting them (allocation pressure) while the session's swap
    transfers are in flight voids the certificate -> the engine abandons
    the host copy (dropping the prefetch with it) and rebuilds by
    recompute."""
    eng, backend = _async_engine(blocks=150)
    fam = [(("sw7", i), 32) for i in range(64)]
    a = make_session(0.0, [Round(64 * 32, 8, None, 0.0)],
                     ideal_time=1.0, sid=78001)
    a.meta["prefix_hashes"] = list(fam)
    b = make_session(0.0, [Round(64 * 32 + 1024, 8, "t", 50.0),
                           Round(64, 8, None, 0.0)],
                     ideal_time=1.0, sid=78002)
    b.meta["prefix_hashes"] = fam + [(("u", 78002, i), 32)
                                     for i in range(32)]
    eng.submit(a)
    now = _tick_until(eng, 0.0, lambda: a.phase == Phase.FINISHED)
    eng.submit(b)
    now = _tick_until(eng, now, lambda: b.phase == Phase.TOOL)
    assert b.kv_state == KVState.SWAPPED
    rec = list(b.meta["swap_pages"])
    shared = [(bid, gen) for bid, gen, private in rec if not private]
    assert shared, "B should have recorded radix-shared blocks"
    assert eng.blocks.certify(shared)
    # drain the swap-out batch so the transfer is genuinely in flight
    now = _tick_until(eng, now, lambda: b.sid in backend.out_futs, limit=3)
    # allocation pressure while in flight: C's prefill digs into the cached
    # shared blocks, bumping their generations
    c = make_session(now, [Round(135 * 32, 8, None, 0.0)],
                     ideal_time=1.0, sid=78003)
    eng.submit(c)
    now = _tick_until(eng, now, lambda: c.phase == Phase.FINISHED)
    assert not eng.blocks.certify(shared)     # certificate void
    backend.out_futs[b.sid].resolve()
    if b.sid in backend.in_futs:
        backend.in_futs[b.sid].resolve()
    now += 60.0                               # tool over: B tries to restore
    now = _tick_until(eng, now, lambda: b.phase == Phase.FINISHED, limit=400)
    assert b.sid in backend.dropped           # prefetch/host copy discarded
    assert eng.host.drops >= 1 and eng.host.hits == 0
    assert eng.host.used_blocks == 0
    # it recomputed: round-1 context was rebuilt, not restored
    assert any(e.kind == ev.EVICT for e in eng.bus.log)
    eng.check_invariants()


def test_sim_swap_cost_accounting_unchanged():
    """Regression: without an async backend the engine still stamps the
    modeled engineered-DMA cost (swap_seconds of the private suffix) on
    every tiered swap-in — the serialized-era accounting, bit-identical."""
    costs = []

    class _Spy(SimBackend):
        def run_batch(self, backend_work, now):
            for s, _ in backend_work.swapins:
                costs.append((s.meta.get("swap_cost_s"),
                              s.meta.get("host_tokens")))
            return super().run_batch(backend_work, now)

    eng = Engine(EngineConfig(total_kv_blocks=2048, block_size=32,
                              token_budget=8192, cpu_slots=4),
                 "fcfs", _Spy(QWEN3, H100))
    eng.policy.on_tool_yield = lambda s, now: (KVAction.OFFLOAD, 0.0)
    s = make_session(0.0, [Round(20_000, 16, "t", 30.0),
                           Round(500, 16, None, 0.0)], ideal_time=10.0)
    finished, _ = run_sim(eng, [s], max_time=1e5)
    assert len(finished) == 1
    assert len(costs) == 1
    cost, host_tokens = costs[0]
    assert cost == eng.host.swap_seconds(host_tokens)
    assert cost > 0.0
    eng.check_invariants()


def test_offload_net_prices_overlapped_swap_in():
    """The co-scheduler stops charging the swap-in as serialized GPU time
    once the backend overlaps it: offload nets strictly higher."""
    from repro.core.coscheduler import (CoSchedulerConfig,
                                        OpportunisticCoScheduler)
    cs = OpportunisticCoScheduler(CoSchedulerConfig(), telem=None,
                                  recompute_time_fn=lambda n: 1.0)
    cs.swap_seconds = lambda n: 0.4
    s = make_session(0.0, [Round(8192, 8, "t", 5.0)], ideal_time=1.0)
    s.resident_len = 8192
    serialized = cs.offload_net(s, 0.0)
    cs.swap_in_overlapped = True
    overlapped = cs.offload_net(s, 0.0)
    assert serialized == pytest.approx(1.0 - 0.4 - 0.5 * 0.4)
    assert overlapped == pytest.approx(1.0 - 0.5 * 0.4)
    assert overlapped > serialized


# ---------------------------------------------------------------------------
# live paged runner: real transfers through the stream
# ---------------------------------------------------------------------------

pytest.importorskip("jax")


def _reduced_cfg():
    from repro.configs.registry import get_config
    return get_config("llama3.2-1b").reduced()


def _run_paged(sids, *, async_swap):
    from repro.core.events import EventBus
    from repro.engine.engine import run_live
    from repro.engine.jax_runner import JaxBackend
    from repro.engine.tools import RealToolExecutor
    backend = JaxBackend(_reduced_cfg(), layout="paged", max_slots=4,
                         max_len=256, async_swap=async_swap)
    bus = EventBus()
    tools = RealToolExecutor(cpu_slots=2, bus=bus)
    eng = Engine(EngineConfig(total_kv_blocks=30, block_size=32,
                              token_budget=256, max_decode_batch=4,
                              decode_granularity=4, cpu_slots=2),
                 "fcfs", backend, bus=bus, tool_exec=tools)
    eng.policy.on_tool_yield = lambda s, now: (KVAction.OFFLOAD, 0.0)
    fam = [(("lsw", i), 32) for i in range(3)]
    sessions = []
    for j, sid in enumerate(sids):
        s = make_session(0.05 * j, [Round(128, 8, "t", 0.05),
                                    Round(32, 6, None, 0.0)],
                         ideal_time=1.0, sid=sid)
        s.meta["prefix_hashes"] = fam + [(("u", sid, 0), 32)]
        sessions.append(s)
    finished, _ = run_live(eng, sessions, timeout=120)
    tools.shutdown()
    eng.check_invariants()
    out = {s.sid: list(s.meta["generated"]) for s in finished}
    stream = backend._impl.stream
    backend.close()
    return out, eng, stream


@pytest.mark.live
def test_paged_async_stream_moves_real_transfers():
    """Forced OFFLOAD on the live paged runner with the stream enabled:
    transfers really flow through the worker (D2H drains + H2D prefetches,
    bounded staging), the tier pairs its stores/hits, and greedy tokens are
    identical to the serialized paged path."""
    sids = [95001, 95002]
    sync_out, _, none_stream = _run_paged(sids, async_swap=False)
    assert none_stream is None
    async_out, eng, stream = _run_paged(sids, async_swap=True)
    assert async_out == sync_out and set(async_out) == set(sids)
    assert stream.d2h_completed >= 1          # drains ran in background
    assert stream.h2d_completed >= 1          # restores were prefetched
    assert stream.d2h_submitted == stream.d2h_completed
    assert stream.h2d_submitted == stream.h2d_completed
    assert stream.staging.max_in_flight <= 2
    assert eng.host.used_blocks == 0 and eng.host.hits >= 1
    outs = [e for e in eng.bus.log if e.kind == ev.SWAP_OUT
            and e.data.get("tier") == "host"]
    ins = [e for e in eng.bus.log if e.kind == ev.SWAP_IN
           and e.data.get("tier") == "host"]
    assert len(outs) == len(ins) >= 1
    eng.blocks.check_consistency()


@pytest.mark.live
@pytest.mark.slow
def test_paged_async_stream_soak():
    """Soak: a wider family over more tool rounds keeps the stream, pool
    and tier invariant-clean (nightly set only)."""
    from repro.engine.engine import run_live
    from repro.engine.jax_runner import JaxBackend
    backend = JaxBackend(_reduced_cfg(), layout="paged", max_slots=6,
                         max_len=512, async_swap=True)
    eng = Engine(EngineConfig(total_kv_blocks=90, block_size=32,
                              token_budget=512, max_decode_batch=6,
                              decode_granularity=4, cpu_slots=4),
                 "fcfs", backend)
    eng.policy.on_tool_yield = lambda s, now: (KVAction.OFFLOAD, 0.0)
    sessions = []
    for j in range(4):
        rounds = [Round(160, 8, "t", 0.05), Round(64, 8, "t", 0.05),
                  Round(64, 8, None, 0.0)]
        sessions.append(make_session(0.1 * j, rounds, ideal_time=1.0,
                                     sid=96000 + j))
    finished, _ = run_live(eng, sessions, timeout=180)
    assert len(finished) == 4
    stream = backend._impl.stream
    assert stream.d2h_completed == stream.d2h_submitted
    assert stream.staging.max_in_flight <= 2
    assert eng.host.used_blocks == 0
    eng.check_invariants()
    backend.close()
