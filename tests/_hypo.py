"""Minimal fallback for ``hypothesis`` in hermetic environments.

Provides just enough of the ``given``/``settings``/``strategies`` surface for
this repo's property tests: each ``@given`` draws a fixed number of seeded
pseudo-random examples instead of doing real shrinking/coverage search. When
the real hypothesis is installed the test modules import it instead.
"""
from __future__ import annotations

import random

_DEFAULT_EXAMPLES = 50
_MAX_EXAMPLES_CAP = 200        # keep tier-1 runtime bounded


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return SearchStrategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> SearchStrategy:
        return SearchStrategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(seq) -> SearchStrategy:
        items = list(seq)
        return SearchStrategy(lambda rng: rng.choice(items))

    @staticmethod
    def lists(elem: SearchStrategy, min_size: int = 0,
              max_size: int = 10) -> SearchStrategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elem.example(rng) for _ in range(n)]
        return SearchStrategy(draw)

    @staticmethod
    def tuples(*elems: SearchStrategy) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: tuple(e.example(rng) for e in elems))


st = _Strategies()


def given(*strategies: SearchStrategy):
    def deco(fn):
        # NOTE: no functools.wraps — pytest must see a zero-arg signature,
        # not the strategy parameters (it would resolve them as fixtures).
        def wrapper():
            rng = random.Random(0)
            n = min(getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES),
                    _MAX_EXAMPLES_CAP)
            for _ in range(n):
                fn(*(s.example(rng) for s in strategies))
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._max_examples = _DEFAULT_EXAMPLES
        return wrapper
    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
