"""Tiered KV-state subsystem tests: pool refcount/CoW invariants, radix
insert/match/evict, host-tier offload round trips, prefix sharing end to
end, and a randomized three-way retention schedule holding the engine's
extended (refcount-aware) invariants."""
import random

import pytest

from repro.configs.qwen3_coder_30b import CONFIG as QWEN3, CONTEXT_LIMIT
from repro.core import events as ev
from repro.core.policies import KVAction
from repro.core.session import Round, make_session
from repro.engine.backend import SimBackend
from repro.engine.engine import Engine, EngineConfig, run_sim
from repro.kvcache import (BlockPool, HostTier, HostTierConfig, RadixIndex,
                           chunk_key_digest, estimate_digest_match)
from repro.models.perf_model import H100
from repro.workloads.generator import WorkloadSpec, generate

BACKEND = SimBackend(QWEN3, H100)


def _engine(policy="mars", blocks=9000, **cfg_kw):
    return Engine(EngineConfig(total_kv_blocks=blocks, block_size=32,
                               token_budget=8192, max_decode_batch=64,
                               decode_granularity=8, cpu_slots=8, **cfg_kw),
                  policy, BACKEND)


# ---------------------------------------------------------------------------
# pool: refcounts + copy-on-write
# ---------------------------------------------------------------------------

def test_pool_basic_alloc_release():
    p = BlockPool(16, 32)
    assert p.alloc(1, 4) and p.free == 12
    assert not p.alloc(2, 13)            # over capacity refused
    assert p.release_all(1) == 4
    assert p.free == 16 and p.physical_in_use == 0
    p.check_consistency()


def test_pool_shared_blocks_freed_only_at_refcount_zero():
    p = BlockPool(16, 32)
    p.alloc(1, 3)
    shared = p.lease(1)
    p.acquire(2, shared)                 # second session references them
    assert p.free == 13                  # no new physical blocks
    assert p.leased_total == 6 and p.physical_in_use == 3
    p.release_all(1)
    assert p.physical_in_use == 3        # still referenced by sid 2
    assert p.free == 13
    p.release_all(2)
    assert p.physical_in_use == 0 and p.free == 16
    p.check_consistency()


def test_pool_no_double_free():
    p = BlockPool(8, 32)
    p.alloc(7, 2)
    assert p.release_all(7) == 2
    assert p.release_all(7) == 0         # second release is a no-op
    p.check_consistency()


def test_pool_copy_on_write_preserves_shared_tail():
    p = BlockPool(16, 32)
    p.alloc(1, 2)
    tail = p.lease(1)[-1]
    p.acquire(2, [tail])                 # shared tail (ref 2)
    assert p.tail_needs_cow(2)
    assert p.copy_on_write(2)
    assert p.lease(2)[-1] != tail        # private copy
    assert p.lease(1)[-1] == tail        # original untouched
    assert p.cow_count == 1
    assert not p.tail_needs_cow(2)
    p.check_consistency()


def test_pool_indexed_block_parks_cached_then_revives():
    p = BlockPool(8, 32)
    p.alloc(1, 3)
    bids = p.lease(1)
    p.index_blocks(bids)
    p.release_all(1)
    assert p.free == 8                   # cached counts as allocatable
    assert p.probe().cached == 3         # ...but content is retained
    p.acquire(2, bids)                   # revive from cache
    assert p.probe().cached == 0 and p.free == 5
    p.release_all(2)
    p.check_consistency()


def test_pool_cached_evicted_under_pressure_with_callback():
    p = BlockPool(4, 32)
    evicted = []
    p.set_evict_callback(evicted.append)
    p.alloc(1, 4)
    p.index_blocks(p.lease(1))
    p.release_all(1)
    assert p.probe().cached == 4
    assert p.alloc(2, 4)                 # forces eviction of cached blocks
    assert len(evicted) == 4
    p.check_consistency()


def test_pool_random_ops_never_leak():
    rng = random.Random(0)
    p = BlockPool(64, 32)
    sids = list(range(6))
    for _ in range(3000):
        sid = rng.choice(sids)
        op = rng.random()
        if op < 0.4:
            p.alloc(sid, rng.randint(1, 8))
        elif op < 0.6:
            donor = rng.choice(sids)
            lease = p.lease(donor)
            if lease:
                k = rng.randint(1, len(lease))
                p.acquire(sid, lease[:k])
        elif op < 0.8:
            p.release_all(sid)
        elif p.lease(sid) and p.free >= 1:
            p.copy_on_write(sid)
        p.check_consistency()


# ---------------------------------------------------------------------------
# radix: insert / match / evict
# ---------------------------------------------------------------------------

def _hashes(seed, n, tail_tokens=32):
    out = [((seed, i), 32) for i in range(n - 1)]
    out.append(((seed, n - 1), tail_tokens))
    return out


def test_radix_insert_match_longest_prefix():
    p = BlockPool(32, 32)
    r = RadixIndex(p, 32)
    p.alloc(1, 4)
    shared = _hashes("fam", 2) + _hashes("u1", 2)
    r.insert(shared, p.lease(1))
    # a second stream sharing only the first two chunks
    other = _hashes("fam", 2) + _hashes("u2", 2)
    m = r.match(other)
    assert [bid for bid, _ in m] == p.lease(1)[:2]
    assert sum(n for _, n in m) == 64
    # identical stream matches fully, including a partial tail
    assert len(r.match(shared)) == 4


def test_radix_partial_tail_chunk_must_match_length():
    p = BlockPool(8, 32)
    r = RadixIndex(p, 32)
    p.alloc(1, 2)
    r.insert(_hashes("x", 2, tail_tokens=20), p.lease(1))
    assert len(r.match(_hashes("x", 2, tail_tokens=20))) == 2
    # same keys, different coverage => tail rejected
    assert len(r.match(_hashes("x", 2, tail_tokens=32))) == 1


def test_radix_eviction_unlinks_subtree():
    p = BlockPool(4, 32)
    r = RadixIndex(p, 32)
    p.alloc(1, 4)
    r.insert(_hashes("a", 4), p.lease(1))
    p.release_all(1)                     # all four park cached
    assert len(r) == 4
    p.alloc(2, 2)                        # evicts LRU cached (root-most first)
    # evicting an interior node drops its unreachable descendants too
    assert len(r) < 4
    assert r.match(_hashes("a", 4)) == []
    p.check_consistency()


# ---------------------------------------------------------------------------
# radix-root digest (cross-replica prefix reuse)
# ---------------------------------------------------------------------------

def test_chunk_key_digest_deterministic_wire_form():
    import hashlib
    key = ("fam", 3, 0)
    want = hashlib.blake2b(repr(key).encode(), digest_size=8).hexdigest()
    assert chunk_key_digest(key) == want
    assert chunk_key_digest(key) == chunk_key_digest(("fam", 3, 0))
    assert chunk_key_digest(key) != chunk_key_digest(("fam", 3, 1))


def test_radix_digest_tracks_anchors_incrementally():
    p = BlockPool(32, 32)
    r = RadixIndex(p, 32)
    p.alloc(1, 4)
    p.alloc(2, 2)
    fam_a = _hashes("a", 2) + _hashes("ua", 2)
    fam_b = _hashes("b", 2)
    r.insert(fam_a, p.lease(1))
    r.insert(fam_b, p.lease(2))
    d = r.digest()
    assert d["indexed_blocks"] == 6
    ents = d["anchors"]
    assert set(ents) == {chunk_key_digest(("a", 0)),
                         chunk_key_digest(("b", 0))}
    ea = ents[chunk_key_digest(("a", 0))]
    assert ea["blocks"] == 4 and ea["depth"] == 4
    eb = ents[chunk_key_digest(("b", 0))]
    assert eb["blocks"] == 2 and eb["depth"] == 2
    # cached per version: no churn, same object back
    assert r.digest() is d
    # a second member under "a" extends nothing: digest unchanged
    r.insert(_hashes("a", 2), p.lease(1)[:2])
    assert r.digest()["anchors"][chunk_key_digest(("a", 0))]["blocks"] == 4


def test_radix_digest_refreshes_on_stats_and_caps_hit_rate():
    """Stats-only changes must invalidate the cached export (the digest
    carries index-wide queries/hits), and a sibling that queried before
    the builder's insert created the anchor must not push the exported
    per-anchor hit_rate above 1."""
    p = BlockPool(16, 32)
    r = RadixIndex(p, 32)
    fam = _hashes("fam", 3)
    r.record_query(anchor=("fam", 0))    # consulted before anything indexed
    p.alloc(1, 3)
    r.insert(fam, p.lease(1))
    d0 = r.digest()
    assert d0["queries"] == 1
    r.record_query(anchor=("fam", 0))    # second sibling, anchor now live
    d1 = r.digest()
    assert d1 is not d0 and d1["queries"] == 2
    for first in (True, True):           # both siblings attach
        r.record_hit(96, first=first, anchor=("fam", 0))
    ent = r.digest()["anchors"][chunk_key_digest(("fam", 0))]
    assert ent["hits"] == 2
    assert ent["hit_rate"] <= 1.0
    # non-first hit tokens also refresh the export
    before = r.digest()
    r.record_hit(32, first=False, anchor=("fam", 0))
    assert r.digest()["hit_tokens"] == before["hit_tokens"] + 32


def test_radix_digest_shrinks_on_eviction():
    p = BlockPool(4, 32)
    r = RadixIndex(p, 32)
    p.alloc(1, 4)
    r.insert(_hashes("a", 4), p.lease(1))
    v0 = r.digest()["v"]
    p.release_all(1)
    p.alloc(2, 4)        # evicts every cached block under the anchor
    d = r.digest()
    assert d["v"] > v0
    assert d["anchors"] == {} and d["indexed_blocks"] == 0


def test_radix_digest_top_k_by_blocks():
    p = BlockPool(64, 32)
    r = RadixIndex(p, 32)
    for i, n in enumerate((5, 3, 1)):
        sid = 10 + i
        p.alloc(sid, n)
        r.insert(_hashes(f"f{i}", n), p.lease(sid))
    d = r.digest(top_k=2)
    assert set(d["anchors"]) == {chunk_key_digest(("f0", 0)),
                                 chunk_key_digest(("f1", 0))}
    assert d["indexed_blocks"] == 9     # totals still index-wide


def test_estimate_digest_match_bounded_by_depth_and_prefix():
    p = BlockPool(32, 32)
    r = RadixIndex(p, 32)
    p.alloc(1, 4)
    r.insert(_hashes("fam", 4), p.lease(1))
    d = r.digest()
    member = _hashes("fam", 2)           # shorter prefix than indexed chain
    assert estimate_digest_match(d, member) == 2
    longer = _hashes("fam", 8)
    assert estimate_digest_match(d, longer) == 4   # capped by depth
    assert estimate_digest_match(d, _hashes("other", 3)) == 0
    assert estimate_digest_match(None, member) == 0
    assert estimate_digest_match({}, member) == 0


# ---------------------------------------------------------------------------
# host tier
# ---------------------------------------------------------------------------

def test_host_tier_occupancy_and_cost_model():
    ht = HostTier(HostTierConfig(capacity_blocks=10, pcie_bw=1e9),
                  bytes_per_token=1e6, block_size=32)
    assert ht.can_store(10) and not ht.can_store(11)
    sec = ht.store(1, tokens=100, blocks=4, now=0.0)
    assert sec == pytest.approx(ht.cfg.base_latency_s + 0.1)
    assert ht.used_blocks == 4
    assert not ht.ready(1, now=sec * 0.5)
    assert ht.ready(1, now=sec + 1e-9)
    assert ht.load(1, now=1.0) == 100
    assert ht.used_blocks == 0 and ht.hit_rate == 1.0


def test_offload_round_trip_restores_resident_len():
    """Force OFFLOAD at every tool yield: the session must restore its exact
    resident_len from the host tier and finish (SWAP_OUT/SWAP_IN tier=host
    events paired)."""
    eng = _engine(policy="fcfs")
    eng.policy.on_tool_yield = lambda s, now: (KVAction.OFFLOAD, 0.0)
    s = make_session(0.0, [Round(50_000, 32, "terminal", 30.0),
                           Round(2_000, 32, None, 0.0)], ideal_time=10.0)
    finished, _ = run_sim(eng, [s], max_time=1e5)
    assert len(finished) == 1
    outs = [e for e in eng.bus.log if e.kind == ev.SWAP_OUT
            and e.data.get("tier") == "host"]
    ins = [e for e in eng.bus.log if e.kind == ev.SWAP_IN
           and e.data.get("tier") == "host"]
    assert len(outs) == 1 and len(ins) == 1
    assert ins[0].data["tokens"] == 50_032      # prefill + round-0 decode
    assert eng.host.hits == 1 and eng.host.used_blocks == 0
    eng.check_invariants()


def test_offload_defers_to_free_when_host_tier_full():
    eng = _engine(policy="fcfs", host_tier_blocks=4)   # 128-token tier
    eng.policy.on_tool_yield = lambda s, now: (KVAction.OFFLOAD, 0.0)
    s = make_session(0.0, [Round(20_000, 16, "terminal", 5.0),
                           Round(500, 16, None, 0.0)], ideal_time=10.0)
    finished, _ = run_sim(eng, [s], max_time=1e5)
    assert len(finished) == 1
    assert eng.host.stores == 0                 # fell back to drop+recompute
    assert any(e.kind == ev.EVICT and e.data.get("reason") == "tool_free"
               for e in eng.bus.log)
    eng.check_invariants()


# ---------------------------------------------------------------------------
# prefix sharing, end to end
# ---------------------------------------------------------------------------

def _family_sessions(shared_tokens=48_000, tail=5_000, gap=200.0):
    """Two sessions sharing a repository-context prefix; the second arrives
    after the first finished building it."""
    fam = [((("fam", i), 32)) for i in range(shared_tokens // 32)]
    mk = lambda arr, seed: make_session(
        arr, [Round(shared_tokens + tail, 64, None, 0.0)], ideal_time=10.0)
    a, b = mk(0.0, 1), mk(gap, 2)
    a.meta["prefix_hashes"] = fam + [((("ua", i), 32))
                                     for i in range(-(-tail // 32))]
    b.meta["prefix_hashes"] = fam + [((("ub", i), 32))
                                     for i in range(-(-tail // 32))]
    return a, b


def test_prefix_sharing_skips_shared_prefill():
    eng = _engine(blocks=12_000)
    a, b = _family_sessions()
    finished, _ = run_sim(eng, [a, b], max_time=1e5)
    assert len(finished) == 2
    assert eng.prefix_hit_tokens >= 48_000
    # the second session computed only its unique tail
    total = sum(s.total_prompt_tokens for s in (a, b))
    assert eng.prefill_tokens_computed <= total - 48_000
    hits = [e for e in eng.bus.log if e.kind == ev.PREFIX_HIT]
    assert hits and hits[0].sid == b.sid
    eng.check_invariants()


def test_prefix_sharing_off_recomputes_everything():
    eng = _engine(blocks=12_000, enable_prefix_sharing=False)
    a, b = _family_sessions()
    finished, _ = run_sim(eng, [a, b], max_time=1e5)
    assert len(finished) == 2
    assert eng.prefix_hit_tokens == 0
    assert eng.prefill_tokens_computed >= sum(
        s.total_prompt_tokens for s in (a, b))
    eng.check_invariants()


def test_duplicate_round0_full_match_triggers_cow():
    """An exact duplicate attaches its entire round-0 context (partial tail
    block included) and must CoW before decoding into it."""
    eng = _engine(blocks=12_000)
    toks = 20_016                       # not block-aligned: partial tail
    h = [(("f", i), 32) for i in range(toks // 32)] + [(("f", "t"), 16)]
    mk = lambda arr: make_session(
        arr, [Round(toks, 32, None, 0.0)], ideal_time=5.0)
    a, b = mk(0.0), mk(100.0)
    a.meta["prefix_hashes"] = list(h)
    b.meta["prefix_hashes"] = list(h)
    finished, _ = run_sim(eng, [a, b], max_time=1e5)
    assert len(finished) == 2
    assert eng.prefix_hit_tokens == toks       # full-duplicate match
    assert eng.blocks.cow_count >= 1
    eng.check_invariants()


def test_boundary_crossing_decode_cows_before_alloc():
    """First decode step both crosses a block boundary (fresh alloc) and
    writes an indexed partial tail (CoW). The copy must target the shared
    tail — regression for alloc-before-CoW, where copy_on_write re-checked
    the freshly alloc'd private block and silently skipped the copy."""
    eng = _engine(blocks=12_000)
    toks = 20_026                    # tail fill 26: 26 + granularity(8) > 32
    h = [(("g", i), 32) for i in range(toks // 32)] + [(("g", "t"), 26)]
    s = make_session(0.0, [Round(toks, 32, None, 0.0)], ideal_time=5.0)
    s.meta["prefix_hashes"] = list(h)
    finished, _ = run_sim(eng, [s], max_time=1e5)
    assert len(finished) == 1
    # round-0 completion indexed the partial tail; the very next decode
    # allocated a boundary block AND took a private copy of the tail
    assert eng.blocks.cow_count >= 1
    eng.check_invariants()


def test_generator_families_share_chunk_keys():
    spec = WorkloadSpec(regime="ILR-1", arrival_rate=0.5, n_sessions=12,
                        seed=4, max_context=CONTEXT_LIMIT, n_families=3,
                        shared_frac=0.7, dup_frac=0.0)
    sessions = generate(spec, QWEN3, H100)
    fams = {}
    for s in sessions:
        assert "prefix_hashes" in s.meta
        hashes = s.meta["prefix_hashes"]
        assert sum(n for _, n in hashes) == s.rounds[0].new_input_tokens
        fams.setdefault(s.meta["family"], []).append(hashes)
    for members in fams.values():
        assert len(members) == 4
        first_keys = [k for k, _ in members[0]]
        for other in members[1:]:
            keys = [k for k, _ in other]
            shared = sum(1 for a, b in zip(first_keys, keys) if a == b)
            assert shared >= 1           # family prefix in common
            assert keys != first_keys    # unique tails differ (dup_frac=0)


def test_generator_keys_distinct_across_workloads():
    """Family ids restart at 0 every generate() call; the workload-spec
    identity baked into each chunk key keeps two workloads fed to one
    engine from false-matching each other's radix blocks."""
    import dataclasses
    spec_a = WorkloadSpec(regime="ILR-1", arrival_rate=0.5, n_sessions=8,
                          seed=1, max_context=CONTEXT_LIMIT, n_families=2)
    spec_b = dataclasses.replace(spec_a, seed=2)
    ka = {k for s in generate(spec_a, QWEN3, H100)
          for k, _ in s.meta.get("prefix_hashes", [])}
    kb = {k for s in generate(spec_b, QWEN3, H100)
          for k, _ in s.meta.get("prefix_hashes", [])}
    assert ka and kb and not (ka & kb)


# ---------------------------------------------------------------------------
# randomized three-way retention schedule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_pin_offload_drop_schedule_holds_invariants(seed):
    rng = random.Random(seed)

    def random_yield(s, now):
        r = rng.random()
        if r < 0.3:
            return KVAction.PIN, rng.choice([5.0, float("inf")])
        if r < 0.6:
            return KVAction.OFFLOAD, 0.0
        if r < 0.7:
            return KVAction.SWAP, 0.0
        return KVAction.FREE, 0.0

    eng = _engine(policy="continuum", blocks=6000)
    eng.policy.on_tool_yield = random_yield
    spec = WorkloadSpec(regime="ILR-1", arrival_rate=1.0, n_sessions=8,
                        seed=seed, max_context=CONTEXT_LIMIT, n_families=2)
    sessions = generate(spec, QWEN3, H100)
    arrivals = sorted(sessions, key=lambda s: s.arrival_time)
    i, now = 0, 0.0
    for _ in range(60_000):
        while i < len(arrivals) and arrivals[i].arrival_time <= now:
            eng.submit(arrivals[i])
            i += 1
        elapsed, prog = eng.tick(now)
        eng.check_invariants()
        if elapsed:
            now += elapsed
        elif not prog:
            nxt = eng.tools.next_event_time()
            t2 = eng.next_timer_event(now)
            cands = [t for t in (nxt, t2) if t is not None]
            if i < len(arrivals):
                cands.append(arrivals[i].arrival_time)
            if eng.waiting:
                cands.append(now + 0.5)
            if not cands:
                break
            now = max(now + 1e-9, min(cands))
        if eng.done() and i >= len(arrivals):
            break
    assert eng.done()
    assert len(eng.finished) + len(eng.rejected) == len(sessions)
    assert eng.blocks.free == eng.blocks.total
    assert eng.blocks.pinned == 0
    if eng.host is not None:
        assert eng.host.used_blocks == 0
