"""Observability plane tests: span-tree assembly from synthetic event
logs (including overlapping tool+swap spans and the abandoned-swap ->
recompute fallback), bucket exclusivity (exact on synthetic input, <=1%
on a real sim run), histogram percentile correctness, Perfetto export
schema validation, the EventBus ring buffer, and the Telemetry
probe/tick split."""
import importlib.util
import json
import os

import pytest

from repro.core import events as ev
from repro.core.events import EventBus
from repro.core.telemetry import Telemetry, TelemetryConfig
from repro.obs import (MetricsRegistry, PLANES, Histogram, Tracer,
                       bind_engine_probes, breakdown_table,
                       dump_events_jsonl, events_from_dicts,
                       export_perfetto, load_events_jsonl)

REPO = os.path.join(os.path.dirname(__file__), "..")

_spec = importlib.util.spec_from_file_location(
    "trace_report", os.path.join(REPO, "scripts", "trace_report.py"))
trace_report = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(trace_report)


# --- synthetic span assembly -------------------------------------------------

def _e(k, t, sid=1, **data):
    return {"kind": k, "t": t, "sid": sid, "data": data}


def _basic_lifetime():
    """submit -> admit -> prefill -> decode -> tool (with an overlapping
    swap-out) -> restore-gated resume -> swap-in -> decode -> finish."""
    return events_from_dicts([
        _e(ev.SUBMIT, 0.0, tokens=128, rounds=2),
        _e(ev.GPU_SUBMIT, 1.0, round=0),
        _e(ev.PREFILL_CHUNK, 2.0, start=1.0, tokens=128, round=0),
        _e(ev.DECODE_STEP, 3.0, start=2.0, tokens=8, round=0),
        _e(ev.GPU_FIRST_TOKEN, 3.0, ttft=3.0),
        _e(ev.GPU_END, 3.0, round=0),
        _e(ev.RETENTION, 3.0, action="OFFLOAD", ttl=0.0, blocks=4),
        _e(ev.SWAP_OUT, 3.0, tokens=128),          # overlaps the tool
        _e(ev.TOOL_ENQUEUE, 3.0, kind="search"),
        _e(ev.TOOL_START, 4.0, kind="search"),
        _e(ev.TOOL_END, 6.0, kind="search", duration=2.0),
        _e(ev.GPU_SUBMIT, 6.5, round=1),           # restore still pending
        _e(ev.SWAP_IN, 7.0, start=6.5, tokens=128),
        _e(ev.DECODE_STEP, 8.0, start=7.0, tokens=8, round=1),
        _e(ev.GPU_END, 8.0, round=1),
        _e(ev.FINISH, 8.0),
    ])


def test_synthetic_exclusive_timeline_partitions_e2e_exactly():
    tr = Tracer.replay(_basic_lifetime())
    cp = tr.critical_path(1)
    assert cp is not None and cp["e2e"] == 8.0
    # exact partition: no float tolerance needed on hand-built input
    assert sum(cp["buckets"].values()) == pytest.approx(8.0, abs=1e-12)
    assert cp["by_kind"] == pytest.approx({
        "admit_wait": 1.0, "prefill": 1.0, "decode": 2.0,
        "tool_queue": 1.0, "tool_exec": 2.0,
        "restore_wait": 0.5, "swap_in": 0.5})
    assert cp["buckets"] == pytest.approx(
        {"gpu": 3.0, "cpu": 3.0, "io": 1.0, "control": 1.0})
    assert cp["dominant_bucket"] in ("gpu", "cpu")
    # segments are contiguous: each starts where the previous ended
    segs = tr.trace(1).segments
    for a, b in zip(segs, segs[1:]):
        assert b.start == pytest.approx(a.end)


def test_span_tree_keeps_overlapping_overlays():
    tr = Tracer.replay(_basic_lifetime())
    tree = tr.span_tree(1)
    assert tree["submitted"] == 0.0 and tree["finished"] == 8.0
    kinds = {sp.kind for r in tree["rounds"] for sp in r["spans"]}
    # the swap-out overlay survives alongside the tool spans it overlaps
    assert {"swap_out", "tool_exec", "retention", "first_token"} <= kinds
    r0 = next(r for r in tree["rounds"] if r["round"] == 0)
    tool = next(sp for sp in r0["spans"] if sp.kind == "tool_exec")
    queue = next(sp for sp in r0["spans"] if sp.kind == "tool_queue")
    swap = next(sp for sp in r0["spans"] if sp.kind == "swap_out")
    # the swap-out overlay lands inside the tool-yield window it overlaps
    assert queue.start <= swap.start <= tool.end


def test_abandoned_swap_recompute_fallback():
    """A swap-out whose restore is abandoned (pool pressure) charges the
    wait so far to the io plane, then falls back to recompute (prefill)
    under sched_wait — and the timeline still partitions e2e."""
    tr = Tracer.replay(events_from_dicts([
        _e(ev.SUBMIT, 0.0),
        _e(ev.GPU_SUBMIT, 0.5, round=0),
        _e(ev.PREFILL_CHUNK, 1.0, start=0.5, tokens=64, round=0),
        _e(ev.GPU_END, 1.0, round=0),
        _e(ev.SWAP_OUT, 1.0, tokens=64),
        _e(ev.TOOL_ENQUEUE, 1.0, kind="t"),
        _e(ev.TOOL_START, 1.0, kind="t"),
        _e(ev.TOOL_END, 2.0, kind="t", duration=1.0),
        _e(ev.SWAP_ABANDON, 3.0, tokens=64),       # restore given up
        _e(ev.GPU_SUBMIT, 3.5, round=1),
        _e(ev.PREFILL_CHUNK, 4.5, start=3.5, tokens=64, round=1),  # recompute
        _e(ev.DECODE_STEP, 5.0, start=4.5, tokens=4, round=1),
        _e(ev.GPU_END, 5.0, round=1),
        _e(ev.FINISH, 5.0),
    ]))
    cp = tr.critical_path(1)
    assert sum(cp["buckets"].values()) == pytest.approx(cp["e2e"], abs=1e-12)
    # 1s of post-tool wait was restore-gated (io), 0.5s ordinary sched wait
    assert cp["by_kind"]["restore_wait"] == pytest.approx(1.0)
    assert cp["by_kind"]["sched_wait"] == pytest.approx(0.5)
    assert "swap_in" not in cp["by_kind"]          # never restored
    assert any(sp.kind == "swap_abandon" for sp in tr.trace(1).spans)


def test_jsonl_round_trip(tmp_path):
    bus = EventBus()
    for e in _basic_lifetime():
        bus.emit(e.kind, e.t, e.sid, **e.data)
    p = tmp_path / "events.jsonl"
    n = dump_events_jsonl(bus, str(p))
    assert n == len(bus.log)
    tr = Tracer.replay(load_events_jsonl(str(p)))
    assert tr.finished_count == 1
    assert sum(tr.critical_path(1)["buckets"].values()) == \
        pytest.approx(8.0, abs=1e-12)


# --- real sim run ------------------------------------------------------------

@pytest.fixture(scope="module")
def sim_tracer():
    from repro.configs.qwen3_coder_30b import CONFIG, CONTEXT_LIMIT
    from repro.engine.backend import SimBackend
    from repro.engine.engine import Engine, EngineConfig, run_sim
    from repro.models.perf_model import H100
    from repro.workloads.generator import WorkloadSpec, generate
    spec = WorkloadSpec(regime="ILR-2", arrival_rate=0.25, n_sessions=10,
                        seed=4, max_context=CONTEXT_LIMIT)
    sessions = generate(spec, CONFIG, H100)
    eng = Engine(EngineConfig(total_kv_blocks=9500, cpu_slots=16),
                 "mars", SimBackend(CONFIG, H100))
    reg = MetricsRegistry()
    tr = Tracer.install(eng, metrics=reg)
    bind_engine_probes(reg, eng)
    finished, _ = run_sim(eng, sessions, max_time=1e5)
    return tr, eng, finished


def test_sim_buckets_partition_e2e_within_tolerance(sim_tracer):
    tr, eng, finished = sim_tracer
    assert tr.finished_count == len(finished) > 0
    for sid in tr.finished_sids():
        cp = tr.critical_path(sid)
        assert sum(cp["buckets"].values()) == \
            pytest.approx(cp["e2e"], rel=0.01)     # acceptance bar: 1%
        assert all(v >= 0 for v in cp["buckets"].values())
    agg = tr.aggregate()
    assert sum(agg["bucket_frac"].values()) == pytest.approx(1.0, rel=1e-6)


def test_sim_e2e_matches_engine_accounting(sim_tracer):
    """The tracer's e2e (finish - submit) agrees with the session's own
    latency accounting for every finished session."""
    tr, _, finished = sim_tracer
    for s in finished:
        cp = tr.critical_path(s.sid)
        assert cp["e2e"] == pytest.approx(s.e2e_latency, rel=1e-9)


def test_sim_tick_events_and_retention_audits(sim_tracer):
    tr, eng, _ = sim_tracer
    assert len(tr.ticks) > 0
    te = tr.ticks[-1].data
    assert set(te["phases"]) == {"tools_control", "upkeep", "form_batch",
                                 "run_batch", "bookkeep"}
    assert te["wall_s"] >= 0
    audits = eng.bus.of_kind(ev.RETENTION)
    assert audits, "trace_ticks must emit retention audit records"
    a = audits[0].data
    assert {"action", "ttl", "blocks", "recompute_s"} <= set(a)
    assert a["action"] in ("FREE", "PIN", "SWAP", "OFFLOAD", "OFFLOAD_DISK")


def test_sim_metrics_histograms_fed(sim_tracer):
    tr, eng, _ = sim_tracer
    snap = tr.metrics.snapshot()
    assert snap["histograms"]["trace.e2e_s"]["count"] == tr.finished_count
    assert snap["histograms"]["trace.tool_s"]["count"] > 0
    assert snap["telemetry"]["active_sessions"] == 0    # drained
    assert snap["events"]["counts"][ev.FINISH] == tr.finished_count
    assert snap["events"]["dropped"] == 0


def test_perfetto_export_schema(sim_tracer, tmp_path):
    tr, _, _ = sim_tracer
    p = tmp_path / "trace.json"
    doc = export_perfetto(tr, str(p))
    assert trace_report.validate_perfetto(doc) == []
    on_disk = json.loads(p.read_text())
    assert trace_report.validate_perfetto(on_disk) == []
    # the report recomputes the same totals from the exported slices
    rows = trace_report.rows_from_perfetto(on_disk)
    assert len(rows) == tr.finished_count
    for r in rows:
        cp = tr.critical_path(r["sid"])
        for plane in PLANES:
            assert r["buckets"][plane] == \
                pytest.approx(cp["buckets"][plane], abs=1e-5)
    assert breakdown_table(rows)                        # renders


def test_trace_report_main_gates_schema(sim_tracer, tmp_path, capsys):
    tr, _, _ = sim_tracer
    good = tmp_path / "good.json"
    export_perfetto(tr, str(good))
    assert trace_report.main([str(good), "--max-rows", "3"]) == 0
    out = capsys.readouterr().out
    assert "finished sessions" in out and "TOTAL" in out
    # a malformed export (session slice without plane) must fail
    doc = json.loads(good.read_text())
    for e in doc["traceEvents"]:
        if e.get("ph") == "X" and "sid" in e.get("args", {}):
            del e["args"]["plane"]
            break
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(doc))
    assert trace_report.main([str(bad)]) == 1


def test_multi_replica_export_has_one_process_per_tracer():
    trs = {}
    for rid in ("replica-a", "replica-b"):
        trs[rid] = Tracer.replay(_basic_lifetime())
    doc = export_perfetto(trs)
    assert trace_report.validate_perfetto(doc) == []
    procs = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("ph") == "M" and e["name"] == "process_name"}
    assert procs == {"replica-a", "replica-b"}


# --- histogram ---------------------------------------------------------------

def test_histogram_percentiles_interpolate():
    h = Histogram(bounds=[10.0, 20.0, 30.0])
    for v in (5.0, 12.0, 14.0, 25.0):
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 4 and s["min"] == 5.0 and s["max"] == 25.0
    assert s["mean"] == pytest.approx(14.0)
    # p50 lands in the (10, 20] bucket, interpolated inside it
    assert 10.0 <= s["p50"] <= 20.0
    # percentiles clamp to observed extremes, never bucket infinities
    assert s["p99"] <= 25.0
    assert Histogram().snapshot()["count"] == 0       # empty is well-formed


def test_histogram_percentiles_against_exact_quantiles():
    h = Histogram()                                    # default log bounds
    vals = [0.001 * (i + 1) for i in range(1000)]      # 1ms .. 1s uniform
    for v in vals:
        h.observe(v)
    # fixed-bucket interpolation should land within a bucket's width of
    # the exact empirical quantile (log buckets: ~77% relative spacing)
    for q in (0.5, 0.95, 0.99):
        exact = vals[int(q * len(vals)) - 1]
        assert h.percentile(q) == pytest.approx(exact, rel=0.5)
    assert h.percentile(1.0) <= h.max


# --- event bus ring + index --------------------------------------------------

def test_eventbus_ring_caps_log_and_counts_drops():
    bus = EventBus(max_log=10)
    for i in range(25):
        bus.emit("k", float(i), i)
    assert len(bus.log) == 10
    assert bus.dropped == 15
    assert [e.t for e in bus.log] == [float(i) for i in range(15, 25)]
    # per-kind index is bounded the same way and stays consistent
    assert [e.t for e in bus.of_kind("k")] == [e.t for e in bus.log]
    # counts keep the true total (monotone, unaffected by the ring)
    assert bus.counts["k"] == 25


def test_eventbus_of_kind_index_matches_log_scan():
    bus = EventBus()
    for i in range(30):
        bus.emit("a" if i % 3 else "b", float(i), i)
    for kind in ("a", "b"):
        assert [e.t for e in bus.of_kind(kind)] == \
            [e.t for e in bus.log if e.kind == kind]
    assert bus.of_kind("missing") == []


def test_eventbus_unbounded_by_default():
    bus = EventBus()
    for i in range(5000):
        bus.emit("k", float(i), i)
    assert len(bus.log) == 5000 and bus.dropped == 0


# --- telemetry probe/tick split ---------------------------------------------

def test_probe_gpu_does_not_advance_hysteresis():
    bus = EventBus()
    t = Telemetry(TelemetryConfig(cpu_slots=2, hysteresis_checks=2), bus)
    bus.emit(ev.TOOL_START, 0.0, 1, kind="x")
    bus.emit(ev.TOOL_START, 0.0, 2, kind="x")          # CPU plane saturated
    for _ in range(5):                                 # probes alone: no flip
        t.probe_gpu(100, 50, 0, 2, 1, 0)
    assert not t.cpu_overloaded
    t.tick()
    assert not t.cpu_overloaded                        # 1 of 2 checks
    t.tick()
    assert t.cpu_overloaded                            # hysteresis met
